"""Unit tests for the optimizer core: SPSA estimator properties, Addax
update semantics (paper eq. 3), baselines equivalences."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import rng, schedules, spsa
from repro.core.addax import AddaxConfig, fused_update, make_addax_step
from repro.core.mezo import make_mezo_step
from repro.core.sgd import make_ipsgd_step


def quad_loss(params, batch):
    """L = 0.5 ||A p - b||^2 on a flat param vector (deterministic)."""
    p = params["w"]
    return 0.5 * jnp.sum((batch["A"] @ p - batch["b"]) ** 2)


def _quad_batch(n=12, d=8, seed=0):
    k1, k2 = jax.random.split(jax.random.key(seed))
    return {"A": jax.random.normal(k1, (n, d)),
            "b": jax.random.normal(k2, (n,))}


def test_spsa_matches_directional_derivative():
    """g0 -> <grad L, z> as eps -> 0 (SPSA is a central difference)."""
    params = {"w": jnp.linspace(-1, 1, 8)}
    batch = _quad_batch()
    seed = jnp.uint32(3)
    g0, _, _ = spsa.spsa_directional_grad(quad_loss, params, batch, seed,
                                          1e-4, mode="fresh")
    z = rng.tree_z(seed, params, jnp.float32)
    grad = jax.grad(quad_loss)(params, batch)
    expected = jnp.vdot(grad["w"], z["w"])
    np.testing.assert_allclose(float(g0), float(expected), rtol=1e-3)


def test_spsa_chain_equals_fresh():
    params = {"w": jnp.linspace(-1, 1, 8)}
    batch = _quad_batch()
    g_c, l_c, p_c = spsa.spsa_directional_grad(quad_loss, params, batch,
                                               jnp.uint32(5), 1e-3, "chain")
    g_f, l_f, p_f = spsa.spsa_directional_grad(quad_loss, params, batch,
                                               jnp.uint32(5), 1e-3, "fresh")
    np.testing.assert_allclose(float(g_c), float(g_f), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(p_c["w"]), np.asarray(p_f["w"]),
                               atol=1e-6)


@pytest.mark.slow
def test_spsa_unbiased_for_smoothed_loss():
    """E_z[g0 z] approximates grad of the Gaussian-smoothed loss; for a
    quadratic, averaging over many seeds recovers grad L."""
    params = {"w": jnp.linspace(-1, 1, 8)}
    batch = _quad_batch()
    grad = jax.grad(quad_loss)(params, batch)["w"]
    acc = jnp.zeros(8)
    n = 600
    for s in range(n):
        seed = jnp.uint32(1000 + s)
        g0, _, _ = spsa.spsa_directional_grad(quad_loss, params, batch,
                                              seed, 1e-4, "fresh")
        acc = acc + g0 * rng.leaf_z(seed, 0, (8,))
    est = acc / n
    # dimension-d ZO noise: loose tolerance, direction must agree strongly
    cos = jnp.vdot(est, grad) / (jnp.linalg.norm(est)
                                 * jnp.linalg.norm(grad))
    assert float(cos) > 0.9


def test_fused_update_matches_equation3():
    """fused_update == theta - lr (alpha g0 z + (1-alpha) g1)."""
    params = {"w": jnp.linspace(-1, 1, 12).reshape(3, 4),
              "v": jnp.ones((5,))}
    g1 = jax.tree_util.tree_map(lambda p: 0.3 * jnp.ones_like(p), params)
    seed = jnp.uint32(77)
    lr, alpha, g0 = 0.01, 0.2, 1.5
    out = fused_update(params, g1, jnp.float32(g0), seed,
                       jnp.float32(lr), alpha)
    z = rng.tree_z(seed, params, jnp.float32)
    for key in params:
        expected = params[key] - lr * (alpha * g0 * z[key]
                                       + (1 - alpha) * g1[key])
        np.testing.assert_allclose(np.asarray(out[key]),
                                   np.asarray(expected), atol=1e-6)


def test_addax_reduces_to_ipsgd_when_alpha0():
    """alpha=0: the ZO term contributes nothing to the update."""
    cfg = AddaxConfig(alpha=0.0, lr=1e-2)
    lr_fn = schedules.constant(cfg.lr)
    batch = _quad_batch()
    params = {"w": jnp.linspace(-1, 1, 8)}
    addax_step = make_addax_step(quad_loss, cfg, lr_fn)
    ip_step = make_ipsgd_step(quad_loss, cfg, lr_fn)
    pa, _ = addax_step(params, jnp.uint32(0), batch, batch)
    pi, _ = ip_step(params, jnp.uint32(0), batch)
    np.testing.assert_allclose(np.asarray(pa["w"]), np.asarray(pi["w"]),
                               atol=1e-6)


def test_mezo_equals_addax_alpha1_zo_only():
    """MeZO == Addax with alpha=1 up to the (unused) FO batch and seed
    domain; verify the update direction is exactly g0 * z."""
    cfg = AddaxConfig(alpha=1.0, lr=1e-2, eps=1e-3)
    lr_fn = schedules.constant(cfg.lr)
    batch = _quad_batch()
    params = {"w": jnp.linspace(-1, 1, 8)}
    step = make_mezo_step(quad_loss, cfg, lr_fn)
    p2, m = step(params, jnp.uint32(4), batch)
    seed = rng.fold_seed(0x3E20, jnp.uint32(4))
    z = rng.leaf_z(seed, 0, (8,))
    expected = params["w"] - cfg.lr * m["g0"] * z
    np.testing.assert_allclose(np.asarray(p2["w"]), np.asarray(expected),
                               atol=1e-6)


@given(alpha=st.floats(0.0, 1.0), lr=st.floats(1e-4, 1e-1))
@settings(max_examples=15, deadline=None)
def test_addax_step_decreases_quadratic(alpha, lr):
    """On a well-conditioned quadratic, a small-lr Addax step does not
    increase the loss (descent property, paper Thm 3.1 regime)."""
    cfg = AddaxConfig(alpha=alpha, lr=min(lr, 1e-2), eps=1e-4)
    lr_fn = schedules.constant(cfg.lr)
    step = make_addax_step(quad_loss, cfg, lr_fn)
    batch = _quad_batch()
    params = {"w": jnp.zeros(8)}
    l0 = quad_loss(params, batch)
    p2, _ = step(params, jnp.uint32(1), batch, batch)
    l1 = quad_loss(p2, batch)
    # allow tiny ZO noise wiggle when alpha ~ 1
    assert float(l1) <= float(l0) + 1e-3 + 0.05 * alpha


@pytest.mark.slow
def test_addax_converges_on_quadratic():
    """1k steps of Addax solve a small least squares to near optimum —
    the CPU-scale analogue of paper Fig. 11."""
    cfg = AddaxConfig(alpha=1e-2, lr=2e-2, eps=1e-4)
    step = jax.jit(make_addax_step(quad_loss, cfg,
                                   schedules.constant(cfg.lr)))
    batch = _quad_batch()
    params = {"w": jnp.zeros(8)}
    for t in range(1000):
        params, m = step(params, jnp.uint32(t), batch, batch)
    w_star = jnp.linalg.lstsq(batch["A"], batch["b"])[0]
    l_star = quad_loss({"w": w_star}, batch)
    assert float(quad_loss(params, batch)) < float(l_star) + 1e-2


def test_grad_clip():
    cfg = AddaxConfig(alpha=0.0, lr=1.0, grad_clip=0.5)
    step = make_addax_step(quad_loss, cfg, schedules.constant(cfg.lr))
    batch = _quad_batch()
    params = {"w": 100.0 * jnp.ones(8)}   # huge gradient
    p2, m = step(params, jnp.uint32(0), batch, batch)
    delta = jnp.linalg.norm(p2["w"] - params["w"])
    assert float(delta) <= 0.5 * 1.0 + 1e-4
