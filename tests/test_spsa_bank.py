"""Property tests for the multi-direction SPSA estimator bank:

* ``n_dirs=1`` reduces *bitwise* to the single-direction path (the
  pre-PR algorithm) — estimator, fused update, and whole Addax/MeZO
  steps;
* the chain walk's arithmetic restore drifts from ``fresh`` ground truth
  by at most a few ulps for every bank size;
* the g0 vector replays exactly from ``(base seed, step)`` — the
  checkpoint/restart story is unchanged by the bank.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import rng, schedules, spsa
from repro.core.addax import AddaxConfig, fused_update, make_addax_step
from repro.core.mezo import make_mezo_step


def quad_loss(params, batch):
    p = params["w"]
    return 0.5 * jnp.sum((batch["A"] @ p - batch["b"]) ** 2)


def _quad_batch(n=12, d=8, seed=0):
    k1, k2 = jax.random.split(jax.random.key(seed))
    return {"A": jax.random.normal(k1, (n, d)),
            "b": jax.random.normal(k2, (n,))}


def _params(d=8):
    return {"w": jnp.linspace(-1, 1, d)}


# --------------------------------------------------------------------------
# n_dirs = 1 bitwise reduction
# --------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["chain", "fresh"])
def test_bank_n1_matches_directional_bitwise(mode):
    params, batch, seed = _params(), _quad_batch(), jnp.uint32(3)
    g_s, l_s, p_s = spsa.spsa_directional_grad(
        quad_loss, params, batch, seed, 1e-3, mode)
    g_b, l_b, p_b = spsa.spsa_bank_grad(
        quad_loss, params, batch, seed, 1e-3, 1, mode)
    assert g_b.shape == (1,)
    np.testing.assert_array_equal(np.asarray(g_s), np.asarray(g_b[0]))
    np.testing.assert_array_equal(np.asarray(l_s), np.asarray(l_b))
    np.testing.assert_array_equal(np.asarray(p_s["w"]), np.asarray(p_b["w"]))


def test_fused_update_vector_n1_matches_scalar_bitwise():
    """A (1,)-shaped g0 bank takes the exact arithmetic path of the
    scalar g0 — the (alpha/n * g0_k) * z_k weight is alpha * g0 for
    n=1."""
    params = {"w": jnp.linspace(-1, 1, 12).reshape(3, 4),
              "v": jnp.ones((5,))}
    g1 = jax.tree_util.tree_map(lambda p: 0.3 * jnp.ones_like(p), params)
    seed, lr = jnp.uint32(77), jnp.float32(0.01)
    g0 = jnp.float32(1.5)
    for fo in (g1, None):
        a = fused_update(params, fo, g0, seed, lr, 0.2)
        b = fused_update(params, fo, jnp.stack([g0]), seed, lr, 0.2)
        for key in params:
            np.testing.assert_array_equal(np.asarray(a[key]),
                                          np.asarray(b[key]))


def _pre_pr_fused_update(params, fo_grads, g0, seed, lr, alpha):
    """The seed repo's single-direction fused update, verbatim — the
    bit-exactness oracle for the n_dirs=1 regression."""
    ids = rng.leaf_ids(params)

    def one(leaf, lid, g1):
        upd = jnp.zeros(leaf.shape, jnp.float32)
        if g0 is not None:
            z = rng.leaf_z(seed, lid, leaf.shape, jnp.float32)
            upd = upd + alpha * g0 * z
        if g1 is not None:
            upd = upd + (1.0 - alpha if g0 is not None else 1.0) * \
                g1.astype(jnp.float32)
        return (leaf.astype(jnp.float32) - lr * upd).astype(leaf.dtype)

    if fo_grads is None:
        return jax.tree_util.tree_map(
            lambda leaf, lid: one(leaf, lid, None), params, ids)
    return jax.tree_util.tree_map(one, params, ids, fo_grads)


def _pre_pr_addax_step(loss_fn, cfg, lr_fn, params, step_idx, b0, b1):
    """The seed repo's Addax step, verbatim (single direction)."""
    seed = rng.fold_seed(0xADDA, step_idx)
    lr = lr_fn(step_idx)
    g0, _, params = spsa.spsa_directional_grad(
        loss_fn, params, b0, seed, cfg.eps, cfg.spsa_mode)
    _, g1 = jax.value_and_grad(loss_fn)(params, b1)
    return _pre_pr_fused_update(params, g1, g0, seed, lr, cfg.alpha)


def test_addax_step_n1_regression_bitwise():
    cfg = AddaxConfig(alpha=5e-3, lr=1e-2, eps=1e-3, n_dirs=1)
    lr_fn = schedules.constant(cfg.lr)
    params, batch = _params(), _quad_batch()
    step = make_addax_step(quad_loss, cfg, lr_fn)
    for t in (0, 7, 123):
        p_new, _ = step(params, jnp.uint32(t), batch, batch)
        p_old = _pre_pr_addax_step(quad_loss, cfg, lr_fn, params,
                                   jnp.uint32(t), batch, batch)
        np.testing.assert_array_equal(np.asarray(p_new["w"]),
                                      np.asarray(p_old["w"]))


def test_mezo_step_n1_regression_bitwise():
    cfg = AddaxConfig(alpha=1.0, lr=1e-2, eps=1e-3, n_dirs=1)
    lr_fn = schedules.constant(cfg.lr)
    params, batch = _params(), _quad_batch()
    step = make_mezo_step(quad_loss, cfg, lr_fn)
    for t in (0, 4, 99):
        p_new, _ = step(params, jnp.uint32(t), batch)
        seed = rng.fold_seed(0x3E20, jnp.uint32(t))
        g0, _, p = spsa.spsa_directional_grad(
            quad_loss, params, batch, seed, cfg.eps, "chain")
        p_old = _pre_pr_fused_update(p, None, g0, seed,
                                     jnp.float32(cfg.lr), 1.0)
        np.testing.assert_array_equal(np.asarray(p_new["w"]),
                                      np.asarray(p_old["w"]))


# --------------------------------------------------------------------------
# chain vs fresh drift
# --------------------------------------------------------------------------

@pytest.mark.parametrize("n_dirs", [1, 2, 4])
def test_chain_restore_drift_vs_fresh(n_dirs):
    """The fused restore/perturb walk accumulates only ulp-level drift in
    the restored parameters, and g0 agrees closely with the fresh
    ground truth, for every bank size."""
    params = {"a": jnp.ones((16, 16), jnp.float32),
              "w": jnp.linspace(-1, 1, 8)}
    batch = _quad_batch()

    def loss(p, b):
        return quad_loss({"w": p["w"]}, b) + 0.1 * jnp.sum(p["a"] ** 2)

    g_c, _, p_c = spsa.spsa_bank_grad(loss, params, batch, jnp.uint32(5),
                                      1e-3, n_dirs, "chain")
    g_f, _, p_f = spsa.spsa_bank_grad(loss, params, batch, jnp.uint32(5),
                                      1e-3, n_dirs, "fresh")
    np.testing.assert_allclose(np.asarray(g_c), np.asarray(g_f), rtol=1e-3)
    for key in params:
        np.testing.assert_allclose(np.asarray(p_c[key]),
                                   np.asarray(p_f[key]), atol=1e-5)


@pytest.mark.parametrize("n_dirs", [2, 4])
def test_bank_directions_match_directional_derivatives(n_dirs):
    """Each g0[k] is the central difference along its own z_k: for a
    quadratic it converges to <grad L, z_k> as eps -> 0."""
    params, batch = _params(), _quad_batch()
    seed = jnp.uint32(11)
    g0, _, _ = spsa.spsa_bank_grad(quad_loss, params, batch, seed, 1e-4,
                                   n_dirs, "fresh")
    grad = jax.grad(quad_loss)(params, batch)["w"]
    for k, s in enumerate(rng.dir_seeds(seed, n_dirs)):
        z = rng.leaf_z(s, 0, (8,))
        np.testing.assert_allclose(float(g0[k]), float(jnp.vdot(grad, z)),
                                   rtol=1e-3)


def test_dir_seeds_distinct_and_stable():
    seeds = rng.dir_seeds(jnp.uint32(42), 8)
    vals = [int(s) for s in seeds]
    assert len(set(vals)) == 8
    assert vals[0] == 42                     # direction 0 = base seed
    assert vals == [int(s) for s in rng.dir_seeds(jnp.uint32(42), 8)]


# --------------------------------------------------------------------------
# checkpoint/restart seed replay
# --------------------------------------------------------------------------

@pytest.mark.parametrize("n_dirs", [1, 3])
def test_g0_invariant_under_seed_replay(n_dirs):
    """Recomputing the bank from (base seed, step) — as a restarted job
    would — reproduces the g0 vector bit for bit."""
    params, batch = _params(), _quad_batch()
    for t in (0, 17, 1000):
        seed = rng.fold_seed(0xADDA, jnp.uint32(t))
        g_a, _, _ = spsa.spsa_bank_grad(quad_loss, params, batch, seed,
                                        1e-3, n_dirs, "chain")
        seed2 = rng.fold_seed(0xADDA, jnp.uint32(t))   # fresh derivation
        g_b, _, _ = spsa.spsa_bank_grad(quad_loss, params, batch, seed2,
                                        1e-3, n_dirs, "chain")
        np.testing.assert_array_equal(np.asarray(g_a), np.asarray(g_b))


def test_bank_step_jits_and_descends():
    """A jitted n_dirs=4 Addax step runs and makes progress on the
    quadratic (the bank is a drop-in for the training loop)."""
    cfg = AddaxConfig(alpha=1e-2, lr=2e-2, eps=1e-4, n_dirs=4)
    step = jax.jit(make_addax_step(quad_loss, cfg,
                                   schedules.constant(cfg.lr)))
    batch = _quad_batch()
    params = {"w": jnp.zeros(8)}
    l0 = float(quad_loss(params, batch))
    for t in range(50):
        params, m = step(params, jnp.uint32(t), batch, batch)
    assert float(quad_loss(params, batch)) < l0
    assert "g0_std" in m
