"""Streaming host runtime (docs/data-pipeline.md): the bucketed/packed
two-stream pipeline and the async-dispatch train loop.

The load-bearing contract is *bitwise stream determinism*: because
batches and ZO perturbations are pure functions of ``(seed, step)``,
prefetching, async dispatch windows, bucket ladders, and restart all
reorder host work without ever changing a value.  These tests pin it:

* prefetch 0 vs 4 and async window W in {1, 4} produce identical
  ``(params, opt_state)`` trajectories — for addax, for addax-adam with
  a variance-adaptive ``bank_schedule`` (fixed-lag feedback), and for
  the DP ``check_moments`` tripwire path;
* restart mid-window (preemption with W=4 in-flight steps) + resume ==
  the uninterrupted run, bit for bit;
* the per-bucket compiled-step cache (``engine.StepCache``) traces once
  per FO width and never retraces;
* packed FO batches are loss-equivalent to the unpacked per-example
  reference (segment-aware attention leaks nothing across examples),
  and packing is rejected loudly where isolation cannot hold;
* stragglers on non-``log_every`` steps leave standalone records.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.addax import AddaxConfig
from repro.core.engine import StepCache
from repro.data.pipeline import AddaxPipeline, PipelineConfig
from repro.data.synthetic import SyntheticTaskConfig, make_corpus
from repro.distributed.fault_tolerance import PreemptionGuard
from repro.train.loop import TrainLoopConfig, run_training
from repro.train.state import build_optimizer


def lm_toy_loss(params, batch):
    """Cheap LM-batch-shaped loss: exercises the full loop machinery
    (two streams, variable FO widths, masks) without a transformer."""
    x = batch["tokens"].astype(jnp.float32)
    t = batch["targets"].astype(jnp.float32)
    m = batch["mask"].astype(jnp.float32)
    h = jnp.tanh(x * params["w"] + params["b"])
    return jnp.sum((h - jnp.tanh(t * 0.01)) ** 2 * m) / (jnp.sum(m) + 1.0)


def _toy_params():
    return {"w": jnp.full((1, 1), 0.01, jnp.float32),
            "b": jnp.zeros((1, 1), jnp.float32)}


def _corpus(n=160, seed=0, name="rte", max_len=64):
    return make_corpus(SyntheticTaskConfig(
        name=name, task="copy", vocab=512, n_examples=n, min_len=12,
        max_len=max_len, seed=seed))


def _pipe(corpus, l_t=32, n_buckets=1, pack=False, seed=0, k0=2, k1=2):
    return AddaxPipeline(corpus, PipelineConfig(
        k0=k0, k1=k1, l_t=l_t, seed=seed, n_buckets=n_buckets, pack=pack))


# the bit-pattern comparator shared with the fig_host_overlap live gate
# (pytest runs from the repo root, so the benchmarks package is on path)
from helpers import tree_bitwise as _tree_bitwise  # noqa: E402


def _run(optimizer, corpus, *, prefetch=0, window=1, sched="", lag=1,
         n_buckets=1, steps=10, n_dirs=None, ckpt=None, guard=None,
         total=None, log_every=1):
    pipe = _pipe(corpus, n_buckets=n_buckets)
    acfg = AddaxConfig(lr=1e-2, alpha=1e-2, eps=1e-3,
                       n_dirs=n_dirs if n_dirs is not None else
                       (4 if sched else 1),
                       bank_schedule=sched)
    opt = build_optimizer(optimizer, lm_toy_loss, acfg)
    params = _toy_params()
    st = opt.init_state(params) if opt.has_state else None
    out = run_training(
        opt, params, pipe,
        TrainLoopConfig(total_steps=total or steps, log_every=log_every,
                        prefetch=prefetch, async_window=window,
                        sched_lag=lag, ckpt_dir=ckpt,
                        ckpt_every=4 if ckpt else 50),
        opt_state=st, guard=guard)
    return out


# --------------------------------------------------------------------------
# bitwise stream determinism
# --------------------------------------------------------------------------

@pytest.mark.parametrize("optimizer,sched", [
    ("addax", ""),
    ("addax-adam", "1:0.05:20.0:0.5"),
])
@pytest.mark.parametrize("prefetch,window", [(4, 1), (0, 4), (4, 4)])
def test_stream_bitwise_vs_synchronous(optimizer, sched, prefetch, window):
    """prefetch/async trajectories == the synchronous loop, params AND
    opt_state, over >= 10 steps — including the variance-adaptive bank
    (its fixed-lag feedback makes n_active window-independent)."""
    corpus = _corpus()
    ref = _run(optimizer, corpus, sched=sched)
    out = _run(optimizer, corpus, prefetch=prefetch, window=window,
               sched=sched)
    assert _tree_bitwise(ref["params"], out["params"])
    assert _tree_bitwise(ref["opt_state"], out["opt_state"])
    # same metric stream too (records may drain late but never differ)
    ref_n = [h.get("n_active") for h in ref["history"]]
    out_n = [h.get("n_active") for h in out["history"]]
    assert ref_n == out_n


def test_stream_bitwise_with_raised_sched_lag():
    """sched_lag > 1 (the overlapping scheduled-bank mode) is still
    window-independent: W=1 and W=4 agree at equal lag."""
    corpus = _corpus()
    a = _run("addax", corpus, sched="1:0.05:20.0:0.5", lag=4, window=1)
    b = _run("addax", corpus, sched="1:0.05:20.0:0.5", lag=4, window=4,
             prefetch=2)
    assert _tree_bitwise(a["params"], b["params"])


def test_stream_bitwise_check_moments_dp1():
    """The check_moments (DP tripwire) path through the async loop:
    drained checksums, window {1, 4}, bitwise params + (m, v)."""
    from repro.launch.mesh import _mk
    from repro.train.state import build_dp_optimizer
    corpus = _corpus()
    mesh = _mk((1,), ("data",))
    outs = {}
    for prefetch, window in ((0, 1), (4, 4)):
        pipe = _pipe(corpus)
        acfg = AddaxConfig(lr=1e-2, alpha=1e-2, eps=1e-3, n_dirs=1)
        opt = build_dp_optimizer("addax-adam", lm_toy_loss, acfg, mesh,
                                 check_moments=True)
        params = _toy_params()
        out = run_training(
            opt, params, pipe,
            TrainLoopConfig(total_steps=10, log_every=1,
                            prefetch=prefetch, async_window=window),
            opt_state=opt.init_state(params))
        outs[(prefetch, window)] = out
    a, b = outs[(0, 1)], outs[(4, 4)]
    assert _tree_bitwise(a["params"], b["params"])
    assert _tree_bitwise(a["opt_state"], b["opt_state"])
    assert all("moments_checksum" in h for h in a["history"])


def test_restart_mid_window_resume(tmp_path):
    """Preemption with W=4 steps in flight: the forced drain checkpoints
    a fully-executed step, and the resumed run lands bitwise on the
    uninterrupted trajectory."""
    corpus = _corpus()
    ref = _run("addax-adam", corpus, total=12,
               ckpt=str(tmp_path / "ref"))

    guard = PreemptionGuard(install_signal=False)
    pipe = _pipe(corpus)
    orig = pipe.step_batches

    def hook(step):
        if step >= 6:           # fires while earlier steps are in flight
            guard.request()
        return orig(step)
    pipe.step_batches = hook
    acfg = AddaxConfig(lr=1e-2, alpha=1e-2, eps=1e-3, n_dirs=1)
    opt = build_optimizer("addax-adam", lm_toy_loss, acfg)
    params = _toy_params()
    cfg = TrainLoopConfig(total_steps=12, log_every=1, async_window=4,
                          prefetch=2, ckpt_dir=str(tmp_path / "mid"),
                          ckpt_every=4)
    mid = run_training(opt, params, pipe, cfg,
                       opt_state=opt.init_state(params), guard=guard)
    assert mid["preempted"] and mid["step"] < 11

    pipe2 = _pipe(corpus)
    opt2 = build_optimizer("addax-adam", lm_toy_loss, acfg)
    params2 = _toy_params()
    fin = run_training(opt2, params2, pipe2, cfg,
                       opt_state=opt2.init_state(params2))
    assert fin["step"] == 11
    assert _tree_bitwise(ref["params"], fin["params"])
    assert _tree_bitwise(ref["opt_state"], fin["opt_state"])


# --------------------------------------------------------------------------
# per-bucket compiled-step cache
# --------------------------------------------------------------------------

def test_step_cache_compiles_once_per_width():
    calls = []

    def step(params, idx, batch):
        calls.append(batch["tokens"].shape)
        return jax.tree_util.tree_map(
            lambda p: p + jnp.float32(batch["tokens"].shape[1]), params), \
            {"loss": jnp.float32(0.0)}

    cache = StepCache(step, donate_argnums=(0,))
    params = {"w": jnp.zeros((2, 2))}

    def mk(width):
        return {"tokens": np.zeros((2, width), np.int32)}

    for width in (32, 64, 32, 64, 32, 32, 64):
        params, _ = cache(params, jnp.uint32(0), mk(width))
    assert cache.n_compiles == 2            # one trace per distinct width
    assert sorted(set(cache.keys)) == [(((2, 32),)), (((2, 64),))]


def test_bucketed_loop_compiles_once_per_edge():
    """A K-bucket FO ladder through the real loop: at most one compile
    per ladder edge, and more than one width actually flows."""
    corpus = _corpus(n=240, name="multirc", max_len=None)
    pipe = _pipe(corpus, l_t=400, n_buckets=4)
    assert len(pipe.fo_widths) > 1
    acfg = AddaxConfig(lr=1e-2, alpha=1e-2, eps=1e-3, n_dirs=1)
    opt = build_optimizer("addax", lm_toy_loss, acfg)
    out = run_training(opt, _toy_params(), pipe,
                       TrainLoopConfig(total_steps=24, log_every=6,
                                       prefetch=2, async_window=4))
    widths = {pipe.step_batches(s)[1]["tokens"].shape[1]
              for s in range(24)}
    assert len(widths) > 1                  # the ladder actually spreads
    assert out["n_compiles"] == len(widths)  # once per seen width, cached


def test_plan_train_buckets_shares_one_cache():
    """launch.steps.plan_train_buckets: one CellPlan per FO width, all
    sharing a single StepCache (bucketed batch1 never retraces)."""
    from repro.configs.base import ShapeCfg
    from repro.launch.mesh import _mk
    from repro.launch.steps import CellOptions, plan_train_buckets
    from repro.models.registry import get_bundle

    bundle = get_bundle("tiny-100m", smoke=True)
    mesh = _mk((1, 1), ("data", "model"))
    shape = ShapeCfg("bucket_smoke", 128, 2, "train")
    opts = CellOptions(optimizer="addax", fo_buckets=(64, 128))
    plans = plan_train_buckets(bundle, shape, mesh, opts)
    assert len(plans) == 2
    assert plans[0].jitted is plans[1].jitted
    assert isinstance(plans[0].jitted, StepCache)
    w0 = plans[0].abstract_args[-1]["tokens"].shape[1]
    w1 = plans[1].abstract_args[-1]["tokens"].shape[1]
    assert {w0, w1} == {64, 128}


# --------------------------------------------------------------------------
# straggler standalone records
# --------------------------------------------------------------------------

def test_straggler_records_on_non_log_steps():
    """Straggler events off the log_every grid used to vanish from the
    metrics; they must emit standalone records with their evidence."""
    corpus = _corpus()
    pipe = _pipe(corpus)
    acfg = AddaxConfig(lr=1e-2, alpha=1e-2, eps=1e-3, n_dirs=1)
    opt = build_optimizer("addax", lm_toy_loss, acfg)
    out = run_training(opt, _toy_params(), pipe,
                       TrainLoopConfig(total_steps=16, log_every=10,
                                       straggler_threshold=1e-12))
    off_grid = [ev.step for ev in out["stragglers"]
                if ev.step % 10 != 0 and ev.step != 15]
    assert off_grid, "threshold=1e-12 must flag off-grid steps"
    standalone = {h["step"] for h in out["history"]
                  if h.get("straggler") and "duration_s" in h}
    assert set(off_grid) <= standalone


# --------------------------------------------------------------------------
# packing correctness (the models/registry loss-mask audit)
# --------------------------------------------------------------------------

def _packed_setup():
    from repro.models.registry import get_bundle
    bundle = get_bundle("tiny-100m", smoke=True)
    corpus = make_corpus(SyntheticTaskConfig(
        name="sst2", task="copy", vocab=bundle.mcfg.vocab,
        n_examples=64, min_len=8, max_len=20))
    corpus += make_corpus(SyntheticTaskConfig(
        name="sst2", task="copy", vocab=bundle.mcfg.vocab,
        n_examples=8, min_len=50, max_len=64, seed=9))
    pipe = AddaxPipeline(corpus, PipelineConfig(
        k0=2, k1=3, l_t=48, pack=True, seed=1))
    return bundle, pipe


@pytest.mark.slow
def test_packed_loss_matches_unpacked_reference():
    """A packed FO batch's loss equals the mask-weighted mean of each
    example's *unpacked* single-row loss: segment-aware attention and the
    per-segment targets/mask leak nothing across pack boundaries."""
    bundle, pipe = _packed_setup()
    _, pb = pipe.step_batches(0)
    assert max(int(r.max()) for r in pb["segments"]) > 1  # actually packed
    params = bundle.init_params(jax.random.key(0))
    jb = {k: jnp.asarray(v) for k, v in pb.items()}
    loss_packed = float(bundle.loss(params, jb))

    width = pb["tokens"].shape[1]
    num = den = 0.0
    for r in range(pb["tokens"].shape[0]):
        for seg in range(1, int(pb["segments"][r].max()) + 1):
            sel = pb["segments"][r] == seg
            n, off = int(sel.sum()), int(np.argmax(sel))
            one = {"tokens": np.zeros((1, width), np.int32),
                   "targets": np.zeros((1, width), np.int32),
                   "mask": np.zeros((1, width), np.float32)}
            for key in one:
                one[key][0, :n] = pb[key][r, off:off + n]
            li = float(bundle.loss(
                params, {k: jnp.asarray(v) for k, v in one.items()}))
            ms = float(one["mask"].sum())
            num, den = num + li * ms, den + ms
    assert den > 0
    np.testing.assert_allclose(loss_packed, num / den, rtol=2e-6)


def test_packed_batch_invariants():
    """Packer output: segments contiguous 1..m then 0-padding, positions
    restart per segment, no target crosses a boundary, mask only where
    segments live."""
    _, pipe = _packed_setup()
    _, pb = pipe.step_batches(3)
    for r in range(pb["tokens"].shape[0]):
        seg = pb["segments"][r]
        m = int(seg.max())
        off = 0
        for s in range(1, m + 1):
            sel = np.where(seg == s)[0]
            assert sel.size and sel[0] == off          # contiguous layout
            assert np.array_equal(sel, np.arange(off, off + sel.size))
            np.testing.assert_array_equal(
                pb["positions"][r, sel], np.arange(sel.size))
            # the boundary token targets nothing
            assert pb["targets"][r, sel[-1]] == 0
            assert pb["mask"][r, sel[-1]] == 0.0
            off += sel.size
        assert np.all(seg[off:] == 0)
        assert np.all(pb["mask"][r][seg == 0] == 0.0)


def test_packing_rejected_where_it_would_leak():
    """Families/impls whose state crosses row positions reject packed
    batches loudly (the loss mask alone cannot isolate examples).  The
    decoder chunked/flash paths are segment-aware now and must *accept*
    them (parity pinned in tests/test_packed_attention.py)."""
    from repro.models.registry import get_bundle
    fake = {"tokens": jnp.zeros((1, 8), jnp.int32),
            "targets": jnp.zeros((1, 8), jnp.int32),
            "mask": jnp.ones((1, 8), jnp.float32),
            "segments": jnp.ones((1, 8), jnp.int32),
            "positions": jnp.zeros((1, 8), jnp.int32)}
    hybrid = get_bundle("zamba2-1.2b", smoke=True)
    with pytest.raises(ValueError, match="packed"):
        hybrid.loss(hybrid.init_params(jax.random.key(0)), fake)
    dec = get_bundle("tiny-100m", smoke=True)
    loss = dec.loss(dec.init_params(jax.random.key(0)), fake,
                    impl="chunked")
    assert np.isfinite(float(loss))
