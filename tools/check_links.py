"""Relative-link checker for the repo's markdown surface (no deps).

CI runs ``python tools/check_links.py``; it scans README.md, DESIGN.md,
ROADMAP.md, docs/, benchmarks/README.md, and tests/README.md for
markdown links ``[text](target)`` and fails on any *relative* target
that does not exist on disk (fragments are stripped; http(s)/mailto
links are out of scope — this is a docs-integrity gate, not a crawler).

Also usable as a library: ``check_files(paths) -> list[str]`` of
"file: broken-target" strings (tests/test_docs.py drives it that way).
"""

from __future__ import annotations

import os
import re

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: default scan set (kept in sync with the docs satellite of PR 4)
DEFAULT_FILES = (
    "README.md",
    "DESIGN.md",
    "ROADMAP.md",
    "CHANGES.md",
    "benchmarks/README.md",
    "tests/README.md",
)
DEFAULT_DIRS = ("docs",)

# [text](target) — non-greedy text, target up to the closing paren
# (no support for parenthesised URLs; none exist in this repo's docs)
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def iter_md_files(repo: str = REPO):
    for rel in DEFAULT_FILES:
        path = os.path.join(repo, rel)
        if os.path.exists(path):
            yield path
    for d in DEFAULT_DIRS:
        root = os.path.join(repo, d)
        if os.path.isdir(root):
            for base, _, names in sorted(os.walk(root)):
                for n in sorted(names):
                    if n.endswith(".md"):
                        yield os.path.join(base, n)


def check_files(paths) -> list:
    """Returns ["relpath: target", ...] for every broken relative link."""
    broken = []
    for path in paths:
        with open(path, encoding="utf-8") as f:
            text = f.read()
        # fenced code blocks may contain [x](y)-looking noise
        text = re.sub(r"```.*?```", "", text, flags=re.S)
        for target in _LINK.findall(text):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            resolved = os.path.normpath(
                os.path.join(os.path.dirname(path), rel))
            if not os.path.exists(resolved):
                broken.append(f"{os.path.relpath(path, REPO)}: {target}")
    return broken


def main(argv=None) -> int:
    paths = list(iter_md_files())
    broken = check_files(paths)
    print(f"[check_links] scanned {len(paths)} markdown files")
    if broken:
        print(f"[check_links] {len(broken)} broken relative link(s):")
        for b in broken:
            print(f"  - {b}")
        return 1
    print("[check_links] all relative links resolve")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
